"""Paged doc cache: the paged layout (global page pool + per-slot page
tables) must be *bit-identical* to the dense layout — the dense engine
is the oracle — through both read paths (the fused Pallas
paged-attention kernel, interpret-mode on CPU, and the dense-view
"gather" oracle it replaces), and the free-list allocators (flat and
per-shard) must survive exhaustion, early release and mixed
retire/admit churn without leaking or double-issuing pages.  The
mesh-sharded pool's greedy parity runs under 8 fake devices in
tests/distributed_checks.py.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import decode as dec
from repro.models import model as model_lib
from repro.models.transformer import RunCtx
from repro.serving import cache as cache_lib
from repro.serving.cache import (PageAllocator, ShardedPageAllocator,
                                 pages_for, shard_pages_for)
from repro.serving.engine import Engine
from repro.serving.config import ServeConfig
from repro.serving.scheduler import Request, Scheduler

ARCHS = ["granite-3-2b", "jamba-1.5-large-398b", "llama3-8b"]
# transformer w/ softcap+GQA, mamba-mix hybrid, plain GQA transformer

IMPLS = ["kernel", "gather"]


def _mk_engines(key, arch, paged_impl="kernel", **kw):
    """One param set, two engines: dense (oracle) and paged."""
    cfg = get_config(arch).reduced()
    if cfg.has_moe:
        cfg = dataclasses.replace(cfg, moe_capacity_factor=8.0)
    model = model_lib.build(cfg)
    params = model.init(key)
    dense = Engine(cfg, params, RunCtx(strategy="full"))
    paged = Engine(cfg, params, RunCtx(strategy="full"),
                   config=ServeConfig(cache_layout="paged",
                                      paged_impl=paged_impl, **kw))
    return cfg, dense, paged


def _mk_req(cfg, n, lq, seed):
    r = np.random.default_rng(seed)
    return (jnp.asarray(r.integers(0, cfg.vocab_size, (1, n)), jnp.int32),
            jnp.asarray(r.integers(0, cfg.vocab_size, (1, lq)), jnp.int32))


# ---------------------------------------------------------------------------
# Engine-level bit-exactness: paged == dense
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("impl", IMPLS)
@pytest.mark.parametrize("arch", ARCHS)
def test_paged_matches_dense_monolithic_and_chunked(arch, impl, key):
    """Greedy tokens must be bit-identical across layouts — through the
    fused kernel and the gather oracle alike — for both the monolithic
    and the chunked prefill path (page_size chosen to not divide the
    document: the last page is partially filled)."""
    cfg, dense, paged = _mk_engines(key, arch, page_size=16,
                                    paged_impl=impl)
    r = np.random.default_rng(0)
    doc = jnp.asarray(r.integers(0, cfg.vocab_size, (2, 50)), jnp.int32)
    query = jnp.asarray(r.integers(0, cfg.vocab_size, (2, 8)), jnp.int32)
    ref = dense.generate(doc, query, max_new_tokens=6).tokens
    out = paged.generate(doc, query, max_new_tokens=6).tokens
    np.testing.assert_array_equal(out, ref)
    out_c = paged.generate(doc, query, max_new_tokens=6,
                           prefill_chunk=16).tokens
    np.testing.assert_array_equal(out_c, ref)


@pytest.mark.parametrize("impl", IMPLS)
def test_paged_doc_length_at_page_boundary(key, impl):
    """A document exactly filling its pages (n == k * page_size) must
    not read a phantom extra page or drop the last row."""
    cfg, dense, paged = _mk_engines(key, "granite-3-2b", page_size=16,
                                    paged_impl=impl)
    doc, query = _mk_req(cfg, 64, 8, 1)          # 64 = 4 * 16 exactly
    ref = dense.generate(doc, query, max_new_tokens=6).tokens
    np.testing.assert_array_equal(
        paged.generate(doc, query, max_new_tokens=6).tokens, ref)
    np.testing.assert_array_equal(
        paged.generate(doc, query, max_new_tokens=6,
                       prefill_chunk=16).tokens, ref)


def test_paged_page_size_not_dividing_prefill_chunk(key):
    """page_size and prefill_chunk need not align: chunks straddle page
    boundaries and the row-scatter write must still be exact."""
    cfg, dense, paged = _mk_engines(key, "granite-3-2b", page_size=24)
    doc, query = _mk_req(cfg, 50, 8, 2)
    ref = dense.generate(doc, query, max_new_tokens=6).tokens
    for chunk in (16, 32):                       # 24 ∤ 16, 24 ∤ 32
        out = paged.generate(doc, query, max_new_tokens=6,
                             prefill_chunk=chunk).tokens
        np.testing.assert_array_equal(out, ref)


def test_paged_cache_layout_validation(key):
    cfg = get_config("granite-3-2b").reduced()
    model = model_lib.build(cfg)
    params = model.init(key)
    with pytest.raises(ValueError, match="cache_layout"):
        ServeConfig(cache_layout="sparse")
    with pytest.raises(ValueError, match="page_size"):
        ServeConfig(cache_layout="paged", page_size=0)
    with pytest.raises(ValueError, match="need a mesh"):
        # cache axes without a mesh: nothing to shard_map the pool over
        Engine(cfg, params, RunCtx(strategy="full", cache_axes=("model",)),
               config=ServeConfig(cache_layout="paged"))
    with pytest.raises(ValueError, match="paged_impl"):
        ServeConfig(cache_layout="paged", paged_impl="dense-view")
    # graduated PR-6 shim: the old keyword spelling is a hard TypeError
    # naming the replacement field
    with pytest.raises(TypeError, match="cache_layout"):
        Engine(cfg, params, RunCtx(strategy="full"), cache_layout="paged")
    whisper = get_config("whisper-tiny").reduced()
    wparams = model_lib.build(whisper).init(key)
    with pytest.raises(ValueError, match="decoder-only"):
        Engine(whisper, wparams, RunCtx(strategy="full"),
               config=ServeConfig(cache_layout="paged"))


# ---------------------------------------------------------------------------
# Layout round-trips (pure cache math, no model)
# ---------------------------------------------------------------------------

def test_dense_paged_round_trip(key):
    """dense -> paged -> dense is exact on the valid prefix, and the
    paged scatter (append path) lands rows where the gather reads them."""
    blocks, b, n, kv, d = 2, 3, 37, 2, 4
    dense = {"k": jax.random.normal(key, (blocks, b, n, kv, d)),
             "v": jax.random.normal(jax.random.fold_in(key, 1),
                                    (blocks, b, n, kv, d))}
    paged = cache_lib.dense_to_paged((dense,), page_size=8)[0]
    assert paged["pt"].shape == (blocks, b, pages_for(n, 8))
    back = cache_lib.paged_to_dense((paged,))[0]
    np.testing.assert_array_equal(np.asarray(back["k"][:, :, :n]),
                                  np.asarray(dense["k"]))
    # scatter a "chunk" at per-slot offsets, read it back via gather
    t = 5
    upd = jax.random.normal(jax.random.fold_in(key, 2), (blocks, b, t, kv, d))
    off = jnp.asarray([0, 7, 30], jnp.int32)     # page-aligned and not
    scat = jax.vmap(dec.paged_scatter, in_axes=(0, 0, 0, None))
    pool = scat(paged["k"], upd, paged["pt"], off)
    view = jax.vmap(dec.paged_gather)(pool, paged["pt"])
    for row in range(b):
        o = int(off[row])
        np.testing.assert_array_equal(np.asarray(view[:, row, o:o + t]),
                                      np.asarray(upd[:, row]))


def test_sharded_round_trip_and_scatter(key):
    """dense -> mesh-sharded paged -> dense is exact on the valid
    prefix, and the strided sharded scatter lands rows where both the
    gather and the (strided) kernel mask expect them — pure cache math,
    no mesh needed (the layout is just arrays)."""
    blocks, b, n, kv, d, ps, S = 2, 3, 37, 2, 4, 8, 4
    dense = {"k": jax.random.normal(key, (blocks, b, n, kv, d)),
             "v": jax.random.normal(jax.random.fold_in(key, 1),
                                    (blocks, b, n, kv, d))}
    paged = cache_lib.dense_to_paged((dense,), page_size=ps, n_shards=S)[0]
    p_shard = cache_lib.table_width(n, ps, S)
    assert paged["pt"].shape == (blocks, S, b, p_shard)
    assert paged["k"].shape[1] == S * b * p_shard
    back = cache_lib.paged_to_dense((paged,))[0]
    np.testing.assert_array_equal(np.asarray(back["k"][:, :, :n]),
                                  np.asarray(dense["k"]))
    # strided scatter through the sharded tables, read back via gather
    t = 5
    upd = jax.random.normal(jax.random.fold_in(key, 2),
                            (blocks, b, t, kv, d))
    off = jnp.asarray([0, 7, 30], jnp.int32)
    scat = jax.vmap(dec.paged_scatter_sharded, in_axes=(0, 0, 0, None))
    pool = scat(paged["k"], upd, paged["pt"], off)
    view = cache_lib.paged_to_dense(
        ({"k": pool, "v": pool, "pt": paged["pt"]},))[0]["k"]
    for row in range(b):
        o = int(off[row])
        np.testing.assert_array_equal(np.asarray(view[:, row, o:o + t]),
                                      np.asarray(upd[:, row]))


def test_write_doc_pages_sharded_layouts(key):
    """The sharded admission paste (dense request and chunked mini-pool
    request alike) must land every logical page on its round-robin
    shard, exactly where the logical-order gather reads it back — pure
    array math, no mesh needed."""
    blocks, kv, d, ps, S, n_slots = 2, 2, 4, 4, 2, 3
    m = 22                                       # 6 logical pages: [3, 3]
    p_shard = cache_lib.table_width(m, ps, S)
    num_pages = n_slots * p_shard * S
    shared = cache_lib.alloc_paged_slots(
        ({"k": jnp.zeros((blocks, 1, m, kv, d)),
          "v": jnp.zeros((blocks, 1, m, kv, d))},),
        n_slots, num_pages, ps, p_shard,
        lambda leaf: leaf, n_shards=S)
    alloc = ShardedPageAllocator(num_pages, S)
    req = {"k": jax.random.normal(key, (blocks, 1, m, kv, d)),
           "v": jax.random.normal(jax.random.fold_in(key, 1),
                                  (blocks, 1, m, kv, d))}
    pages = alloc.reserve(pages_for(m, ps))
    out = cache_lib.write_doc_pages(shared, (req,), 1, pages, ps)
    dense = cache_lib.paged_to_dense(out)[0]
    np.testing.assert_array_equal(np.asarray(dense["k"][:, 1, :m]),
                                  np.asarray(req["k"][:, 0]))
    # chunked-admission twin: stream the same rows into a sharded
    # mini-pool, then fast-path copy its pages across
    mini = cache_lib.alloc_doc_caches(
        _MiniCfg(blocks, kv, d), 1, m, page_size=ps, n_shards=S)
    doc_len = jnp.zeros((1,), jnp.int32)
    for off in (0, 10, 17):                      # ragged chunk boundaries
        t = min(m, [10, 7, m - 17][[0, 10, 17].index(off)])
        upd = ({"k": req["k"][:, :, off:off + t],
                "v": req["v"][:, :, off:off + t]},)
        mini = cache_lib.append_doc_chunk(mini, upd, doc_len)
        doc_len = doc_len + t
    pages2 = alloc.reserve(pages_for(m, ps))
    out2 = cache_lib.write_doc_pages(out, mini, 2, pages2, ps)
    dense2 = cache_lib.paged_to_dense(out2)[0]
    np.testing.assert_array_equal(np.asarray(dense2["k"][:, 2, :m]),
                                  np.asarray(req["k"][:, 0]))
    # slot 1 untouched by slot 2's paste
    np.testing.assert_array_equal(np.asarray(dense2["k"][:, 1, :m]),
                                  np.asarray(req["k"][:, 0]))
    alloc.release(pages)
    alloc.release(pages2)
    assert alloc.free_pages == num_pages


class _MiniCfg:
    """Just enough config surface for alloc_doc_caches' attention arm."""

    def __init__(self, num_blocks, kv, d):
        self.num_blocks = num_blocks
        self.num_kv_heads = kv
        self.head_dim = d
        self.block_pattern = [type("K", (), {"mixer": "attn",
                                             "window": 0,
                                             "moe": False})()]


def test_paged_kernel_matches_gather_mask_semantics(key):
    """The fused kernel and the gather oracle must agree (to float
    tolerance) on (out, lse) across window / start / strided-layout
    combinations — including fully-masked slots (valid_len = 0)."""
    rng = np.random.default_rng(3)
    b, t, h, kv, d = 3, 4, 4, 2, 16
    npool, ps, p = 12, 8, 3
    q = jnp.asarray(rng.standard_normal((b, t, h, d)), jnp.float32)
    pk = jnp.asarray(rng.standard_normal((npool, ps, kv, d)), jnp.float32)
    pv = jnp.asarray(rng.standard_normal((npool, ps, kv, d)), jnp.float32)
    pt = jnp.asarray(rng.integers(0, npool, (b, p)), jnp.int32)
    vl = jnp.asarray([0, 10, 24], jnp.int32)
    st = jnp.asarray([0, 3, 0], jnp.int32)
    for stride, offset in [(1, 0), (4, 2)]:
        for window in (0, 7):
            for softcap in (None, 20.0):
                outs = [dec.paged_partial_lse(
                    q, pk, pv, pt, valid_len=vl, row_base=vl, start=st,
                    window=window, softcap=softcap, page_stride=stride,
                    page_offset=offset, impl=impl)
                    for impl in ("kernel", "gather")]
                np.testing.assert_allclose(
                    np.asarray(outs[0][0]), np.asarray(outs[1][0]),
                    atol=2e-5)
                np.testing.assert_allclose(
                    np.minimum(np.asarray(outs[0][1]), 1e9),
                    np.minimum(np.asarray(outs[1][1]), 1e9), atol=2e-5)


# ---------------------------------------------------------------------------
# Scheduler over the paged pool
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("prefill_chunk", [None, 16])
def test_paged_scheduler_matches_single_requests(key, prefill_chunk):
    """Mixed-length requests through the shared page pool must match each
    request generated alone — monolithic and streamed admissions."""
    cfg, dense, paged = _mk_engines(key, "granite-3-2b", page_size=16)
    d1, q1 = _mk_req(cfg, 64, 8, 1)
    d2, q2 = _mk_req(cfg, 24, 4, 2)
    ref1 = dense.generate(d1, q1, max_new_tokens=10).tokens[0]
    ref2 = dense.generate(d2, q2, max_new_tokens=4).tokens[0]
    sch = Scheduler(paged, config=ServeConfig(
        n_slots=2, decode_chunk=3, prefill_chunk=prefill_chunk))
    sch.submit(Request("long", d1, q1, max_new_tokens=10))
    sch.submit(Request("short", d2, q2, max_new_tokens=4))
    res = sch.run()
    np.testing.assert_array_equal(res["long"].tokens, np.asarray(ref1))
    np.testing.assert_array_equal(res["short"].tokens, np.asarray(ref2))


@pytest.mark.parametrize("prefill_chunk", [None, 16])
def test_pool_exhaustion_queues_and_recovers(key, prefill_chunk):
    """A pool too small for two long docs serializes them (deferral, not
    corruption): every request still matches its solo generation, and
    all pages return to the free list at the end."""
    cfg, dense, paged = _mk_engines(key, "granite-3-2b", page_size=16)
    d1, q1 = _mk_req(cfg, 64, 8, 1)              # 4 pages
    d2, q2 = _mk_req(cfg, 64, 8, 2)              # 4 pages
    d3, q3 = _mk_req(cfg, 24, 4, 3)              # 2 pages
    refs = {"a": dense.generate(d1, q1, max_new_tokens=6).tokens[0],
            "b": dense.generate(d2, q2, max_new_tokens=6).tokens[0],
            "c": dense.generate(d3, q3, max_new_tokens=4).tokens[0]}
    sch = Scheduler(paged, config=ServeConfig(
        cache_layout="paged", page_size=16,
        n_slots=3, decode_chunk=2, num_pages=5,
        prefill_chunk=prefill_chunk))
    sch.submit(Request("a", d1, q1, max_new_tokens=6))
    sch.submit(Request("b", d2, q2, max_new_tokens=6))
    sch.submit(Request("c", d3, q3, max_new_tokens=4))
    res = sch.run()
    for rid, ref in refs.items():
        np.testing.assert_array_equal(res[rid].tokens, np.asarray(ref))
    assert sch.admission_deferrals > 0           # the pool did push back
    assert sch._allocator.free_pages == sch.num_pages   # all released


def test_request_larger_than_pool_rejected(key):
    """A reservation no amount of waiting can satisfy fails loudly at
    validation (queueing it forever would deadlock the scheduler)."""
    cfg, dense, paged = _mk_engines(key, "granite-3-2b", page_size=16)
    doc, query = _mk_req(cfg, 64, 8, 1)          # needs 4 pages
    sch = Scheduler(paged, config=ServeConfig(cache_layout="paged",
                                              page_size=16,
                                              n_slots=2, decode_chunk=2,
                                              num_pages=2,
                                              doc_capacity=64))
    sch.submit(Request("big", doc, query, max_new_tokens=4))
    with pytest.raises(ValueError, match="pool holds 2"):
        sch.run()
    assert len(sch.pending) == 1                 # not silently dropped


def test_pages_released_on_early_stop(key):
    """A stop token retires the slot mid-budget; its pages must come back
    (release-on-completion) and be reusable by a later admission."""
    cfg, dense, paged = _mk_engines(key, "granite-3-2b", page_size=16)
    doc, query = _mk_req(cfg, 64, 8, 1)
    ref = dense.generate(doc, query, max_new_tokens=8).tokens[0]
    stop = int(ref[2])
    d2, q2 = _mk_req(cfg, 64, 8, 2)
    ref2 = dense.generate(d2, q2, max_new_tokens=4).tokens[0]
    # pool fits exactly one 64-token doc: the second admission *requires*
    # the first one's early release
    sch = Scheduler(paged, config=ServeConfig(cache_layout="paged",
                                              page_size=16,
                                              n_slots=2, decode_chunk=4,
                                              num_pages=4))
    sch.submit(Request("stopper", doc, query, max_new_tokens=8,
                       stop_token=stop))
    sch.submit(Request("next", d2, q2, max_new_tokens=4))
    res = sch.run()
    assert res["stopper"].stopped
    np.testing.assert_array_equal(res["next"].tokens, np.asarray(ref2))
    assert sch._allocator.free_pages == 4


def test_paged_scheduler_with_apb_prefill(key):
    """Admissions through the APB (augmented-layout, host-loop) prefill:
    the local-block doc cache pages into the pool like any dense cache."""
    from repro.core.splitting import make_layout
    cfg = get_config("granite-3-2b").reduced()
    model = model_lib.build(cfg)
    params = model.init(key)
    n, lq = 64, 8
    lay = make_layout(n, lq, 4, anchor_frac=cfg.anchor_frac,
                      passing_frac=cfg.passing_frac)
    dense = Engine(cfg, params, RunCtx(strategy="apb", layout=lay))
    paged = Engine(cfg, params, RunCtx(strategy="apb", layout=lay),
                   config=ServeConfig(cache_layout="paged", page_size=16))
    r = np.random.default_rng(1)
    doc = jnp.asarray(r.integers(0, cfg.vocab_size, (1, n)), jnp.int32)
    query = jnp.asarray(r.integers(0, cfg.vocab_size, (1, lq)), jnp.int32)
    ref = dense.generate(doc, query, max_new_tokens=6).tokens[0]
    sch = Scheduler(paged, config=ServeConfig(n_slots=2, decode_chunk=3))
    sch.submit(Request("apb", doc, query, max_new_tokens=6))
    res = sch.run()
    np.testing.assert_array_equal(res["apb"].tokens, np.asarray(ref))


def test_paged_scheduler_hybrid_ssm(key):
    """Hybrid attention+mamba: mamba states stay per-slot dense while
    attention pages through the pool; idle slots must not perturb it."""
    cfg, dense, paged = _mk_engines(key, "jamba-1.5-large-398b",
                                    page_size=16)
    doc, query = _mk_req(cfg, 32, 8, 5)
    ref = dense.generate(doc, query, max_new_tokens=6).tokens[0]
    sch = Scheduler(paged, config=ServeConfig(n_slots=3,
                                              decode_chunk=4))  # 2 idle
    sch.submit(Request("solo", doc, query, max_new_tokens=6))
    res = sch.run()
    np.testing.assert_array_equal(res["solo"].tokens, np.asarray(ref))


# ---------------------------------------------------------------------------
# PageAllocator unit behaviour
# ---------------------------------------------------------------------------

def test_allocator_exhaustion_and_release():
    a = PageAllocator(4)
    r1 = a.reserve(3)
    assert sorted(r1) == [0, 1, 2] and a.free_pages == 1
    assert a.reserve(2) is None                  # exhausted: no partial take
    assert a.free_pages == 1                     # failed reserve takes nothing
    r2 = a.reserve(1)
    assert a.free_pages == 0
    a.release(r1)
    assert a.free_pages == 3
    with pytest.raises(ValueError, match="double release"):
        a.release(r1)
    a.release(r2)
    assert a.free_pages == 4


def test_allocator_churn_no_fragmentation():
    """Page-granular free lists cannot fragment: after arbitrary mixed
    retire/admit churn, any reservation <= free_pages succeeds and no
    page is ever issued twice concurrently."""
    rng = np.random.default_rng(0)
    a = PageAllocator(16)
    held = []
    for _ in range(200):
        if held and rng.random() < 0.45:
            a.release(held.pop(rng.integers(len(held))))
        else:
            n = int(rng.integers(1, 5))
            r = a.reserve(n)
            if r is None:
                assert a.free_pages < n          # only exhaustion defers
            else:
                held.append(r)
        live = [p for r in held for p in r]
        assert len(live) == len(set(live))       # no double issue
        assert len(live) + a.free_pages == 16    # conservation
    for r in held:
        a.release(r)
    assert a.free_pages == 16


def test_pages_for():
    assert pages_for(0, 8) == 1                  # empty still pins a page
    assert pages_for(1, 8) == 1
    assert pages_for(8, 8) == 1
    assert pages_for(9, 8) == 2
    assert pages_for(64, 16) == 4
    with pytest.raises(ValueError):
        pages_for(8, 0)


def test_shard_pages_for():
    """Round-robin striping: per-shard counts sum to the logical total
    and differ by at most one page."""
    assert shard_pages_for(64, 16, 4) == [1, 1, 1, 1]
    assert shard_pages_for(65, 16, 4) == [2, 1, 1, 1]     # 5 pages
    assert shard_pages_for(8, 16, 4) == [1, 0, 0, 0]      # 1 page
    for n in (0, 1, 17, 100, 129):
        for s in (1, 2, 4, 8):
            per = shard_pages_for(n, 16, s)
            assert sum(per) == pages_for(n, 16)
            assert max(per) - min(per) <= 1


def test_sharded_allocator_all_or_nothing():
    """A reservation one shard cannot satisfy takes nothing anywhere —
    a half grant would deadlock against another half grant."""
    a = ShardedPageAllocator(8, 4)               # 2 pages per shard
    g1 = a.reserve(8)                            # 2 per shard: fills it
    assert a.free_pages == 0
    assert [len(s) for s in g1] == [2, 2, 2, 2]
    a.release(g1)
    g2 = a.reserve(5)                            # needs [2,1,1,1]
    assert [len(s) for s in g2] == [2, 1, 1, 1]
    assert a.shard_free(0) == 0 and a.shard_free(1) == 1
    assert a.reserve(2) is None                  # shard 0 exhausted...
    assert a.free_pages == 3                     # ...and nothing taken
    assert a.reserve(1) is None                  # page 0 always lands on
    a.release(g2)                                # shard 0 — still blocked
    assert a.reserve(1) is not None


def test_sharded_allocator_single_page_needs_shard_zero():
    a = ShardedPageAllocator(8, 4)
    g = a.reserve(2)                             # [1,1,0,0]
    assert a.reserve(8) is None                  # shards 0/1 short
    assert a.free_pages == 6
    a.release(g)
    assert a.fits(8) and not a.fits(9)           # 9 -> [3,2,2,2] > 2/shard
    with pytest.raises(ValueError):
        ShardedPageAllocator(6, 4)               # not an even split
    with pytest.raises(ValueError):
        a.release([[99], [], [], []])            # foreign page id
