"""Paged doc cache: the paged layout (global page pool + per-slot page
tables) must be *bit-identical* to the dense layout — the dense engine
is the oracle — and the free-list allocator must survive exhaustion,
early release and mixed retire/admit churn without leaking or
double-issuing pages.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import decode as dec
from repro.models import model as model_lib
from repro.models.transformer import RunCtx
from repro.serving import cache as cache_lib
from repro.serving.cache import PageAllocator, pages_for
from repro.serving.engine import Engine
from repro.serving.scheduler import Request, Scheduler

ARCHS = ["granite-3-2b", "jamba-1.5-large-398b", "llama3-8b"]
# transformer w/ softcap+GQA, mamba-mix hybrid, plain GQA transformer


def _mk_engines(key, arch, **kw):
    """One param set, two engines: dense (oracle) and paged."""
    cfg = get_config(arch).reduced()
    if cfg.has_moe:
        cfg = dataclasses.replace(cfg, moe_capacity_factor=8.0)
    model = model_lib.build(cfg)
    params = model.init(key)
    dense = Engine(cfg, params, RunCtx(strategy="full"))
    paged = Engine(cfg, params, RunCtx(strategy="full"),
                   cache_layout="paged", **kw)
    return cfg, dense, paged


def _mk_req(cfg, n, lq, seed):
    r = np.random.default_rng(seed)
    return (jnp.asarray(r.integers(0, cfg.vocab_size, (1, n)), jnp.int32),
            jnp.asarray(r.integers(0, cfg.vocab_size, (1, lq)), jnp.int32))


# ---------------------------------------------------------------------------
# Engine-level bit-exactness: paged == dense
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ARCHS)
def test_paged_matches_dense_monolithic_and_chunked(arch, key):
    """Greedy tokens must be bit-identical across layouts for both the
    monolithic and the chunked prefill path (page_size chosen to not
    divide the document: the last page is partially filled)."""
    cfg, dense, paged = _mk_engines(key, arch, page_size=16)
    r = np.random.default_rng(0)
    doc = jnp.asarray(r.integers(0, cfg.vocab_size, (2, 50)), jnp.int32)
    query = jnp.asarray(r.integers(0, cfg.vocab_size, (2, 8)), jnp.int32)
    ref = dense.generate(doc, query, max_new_tokens=6).tokens
    out = paged.generate(doc, query, max_new_tokens=6).tokens
    np.testing.assert_array_equal(out, ref)
    out_c = paged.generate(doc, query, max_new_tokens=6,
                           prefill_chunk=16).tokens
    np.testing.assert_array_equal(out_c, ref)


def test_paged_doc_length_at_page_boundary(key):
    """A document exactly filling its pages (n == k * page_size) must
    not read a phantom extra page or drop the last row."""
    cfg, dense, paged = _mk_engines(key, "granite-3-2b", page_size=16)
    doc, query = _mk_req(cfg, 64, 8, 1)          # 64 = 4 * 16 exactly
    ref = dense.generate(doc, query, max_new_tokens=6).tokens
    np.testing.assert_array_equal(
        paged.generate(doc, query, max_new_tokens=6).tokens, ref)
    np.testing.assert_array_equal(
        paged.generate(doc, query, max_new_tokens=6,
                       prefill_chunk=16).tokens, ref)


def test_paged_page_size_not_dividing_prefill_chunk(key):
    """page_size and prefill_chunk need not align: chunks straddle page
    boundaries and the row-scatter write must still be exact."""
    cfg, dense, paged = _mk_engines(key, "granite-3-2b", page_size=24)
    doc, query = _mk_req(cfg, 50, 8, 2)
    ref = dense.generate(doc, query, max_new_tokens=6).tokens
    for chunk in (16, 32):                       # 24 ∤ 16, 24 ∤ 32
        out = paged.generate(doc, query, max_new_tokens=6,
                             prefill_chunk=chunk).tokens
        np.testing.assert_array_equal(out, ref)


def test_paged_cache_layout_validation(key):
    cfg = get_config("granite-3-2b").reduced()
    model = model_lib.build(cfg)
    params = model.init(key)
    with pytest.raises(ValueError, match="cache_layout"):
        Engine(cfg, params, RunCtx(strategy="full"), cache_layout="sparse")
    with pytest.raises(ValueError, match="page_size"):
        Engine(cfg, params, RunCtx(strategy="full"), cache_layout="paged",
               page_size=0)
    with pytest.raises(ValueError, match="single-host"):
        Engine(cfg, params, RunCtx(strategy="full", cache_axes=("model",)),
               cache_layout="paged")
    whisper = get_config("whisper-tiny").reduced()
    wparams = model_lib.build(whisper).init(key)
    with pytest.raises(ValueError, match="decoder-only"):
        Engine(whisper, wparams, RunCtx(strategy="full"),
               cache_layout="paged")


# ---------------------------------------------------------------------------
# Layout round-trips (pure cache math, no model)
# ---------------------------------------------------------------------------

def test_dense_paged_round_trip(key):
    """dense -> paged -> dense is exact on the valid prefix, and the
    paged scatter (append path) lands rows where the gather reads them."""
    blocks, b, n, kv, d = 2, 3, 37, 2, 4
    dense = {"k": jax.random.normal(key, (blocks, b, n, kv, d)),
             "v": jax.random.normal(jax.random.fold_in(key, 1),
                                    (blocks, b, n, kv, d))}
    paged = cache_lib.dense_to_paged((dense,), page_size=8)[0]
    assert paged["pt"].shape == (blocks, b, pages_for(n, 8))
    back = cache_lib.paged_to_dense((paged,))[0]
    np.testing.assert_array_equal(np.asarray(back["k"][:, :, :n]),
                                  np.asarray(dense["k"]))
    # scatter a "chunk" at per-slot offsets, read it back via gather
    t = 5
    upd = jax.random.normal(jax.random.fold_in(key, 2), (blocks, b, t, kv, d))
    off = jnp.asarray([0, 7, 30], jnp.int32)     # page-aligned and not
    scat = jax.vmap(dec.paged_scatter, in_axes=(0, 0, 0, None))
    pool = scat(paged["k"], upd, paged["pt"], off)
    view = jax.vmap(dec.paged_gather)(pool, paged["pt"])
    for row in range(b):
        o = int(off[row])
        np.testing.assert_array_equal(np.asarray(view[:, row, o:o + t]),
                                      np.asarray(upd[:, row]))


# ---------------------------------------------------------------------------
# Scheduler over the paged pool
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("prefill_chunk", [None, 16])
def test_paged_scheduler_matches_single_requests(key, prefill_chunk):
    """Mixed-length requests through the shared page pool must match each
    request generated alone — monolithic and streamed admissions."""
    cfg, dense, paged = _mk_engines(key, "granite-3-2b", page_size=16)
    d1, q1 = _mk_req(cfg, 64, 8, 1)
    d2, q2 = _mk_req(cfg, 24, 4, 2)
    ref1 = dense.generate(d1, q1, max_new_tokens=10).tokens[0]
    ref2 = dense.generate(d2, q2, max_new_tokens=4).tokens[0]
    sch = Scheduler(paged, n_slots=2, decode_chunk=3,
                    prefill_chunk=prefill_chunk)
    sch.submit(Request("long", d1, q1, max_new_tokens=10))
    sch.submit(Request("short", d2, q2, max_new_tokens=4))
    res = sch.run()
    np.testing.assert_array_equal(res["long"].tokens, np.asarray(ref1))
    np.testing.assert_array_equal(res["short"].tokens, np.asarray(ref2))


@pytest.mark.parametrize("prefill_chunk", [None, 16])
def test_pool_exhaustion_queues_and_recovers(key, prefill_chunk):
    """A pool too small for two long docs serializes them (deferral, not
    corruption): every request still matches its solo generation, and
    all pages return to the free list at the end."""
    cfg, dense, paged = _mk_engines(key, "granite-3-2b", page_size=16)
    d1, q1 = _mk_req(cfg, 64, 8, 1)              # 4 pages
    d2, q2 = _mk_req(cfg, 64, 8, 2)              # 4 pages
    d3, q3 = _mk_req(cfg, 24, 4, 3)              # 2 pages
    refs = {"a": dense.generate(d1, q1, max_new_tokens=6).tokens[0],
            "b": dense.generate(d2, q2, max_new_tokens=6).tokens[0],
            "c": dense.generate(d3, q3, max_new_tokens=4).tokens[0]}
    sch = Scheduler(paged, n_slots=3, decode_chunk=2, num_pages=5,
                    prefill_chunk=prefill_chunk)
    sch.submit(Request("a", d1, q1, max_new_tokens=6))
    sch.submit(Request("b", d2, q2, max_new_tokens=6))
    sch.submit(Request("c", d3, q3, max_new_tokens=4))
    res = sch.run()
    for rid, ref in refs.items():
        np.testing.assert_array_equal(res[rid].tokens, np.asarray(ref))
    assert sch.admission_deferrals > 0           # the pool did push back
    assert sch._allocator.free_pages == sch.num_pages   # all released


def test_request_larger_than_pool_rejected(key):
    """A reservation no amount of waiting can satisfy fails loudly at
    validation (queueing it forever would deadlock the scheduler)."""
    cfg, dense, paged = _mk_engines(key, "granite-3-2b", page_size=16)
    doc, query = _mk_req(cfg, 64, 8, 1)          # needs 4 pages
    sch = Scheduler(paged, n_slots=2, decode_chunk=2, num_pages=2,
                    doc_capacity=64)
    sch.submit(Request("big", doc, query, max_new_tokens=4))
    with pytest.raises(ValueError, match="pool holds 2"):
        sch.run()
    assert len(sch.pending) == 1                 # not silently dropped


def test_pages_released_on_early_stop(key):
    """A stop token retires the slot mid-budget; its pages must come back
    (release-on-completion) and be reusable by a later admission."""
    cfg, dense, paged = _mk_engines(key, "granite-3-2b", page_size=16)
    doc, query = _mk_req(cfg, 64, 8, 1)
    ref = dense.generate(doc, query, max_new_tokens=8).tokens[0]
    stop = int(ref[2])
    d2, q2 = _mk_req(cfg, 64, 8, 2)
    ref2 = dense.generate(d2, q2, max_new_tokens=4).tokens[0]
    # pool fits exactly one 64-token doc: the second admission *requires*
    # the first one's early release
    sch = Scheduler(paged, n_slots=2, decode_chunk=4, num_pages=4)
    sch.submit(Request("stopper", doc, query, max_new_tokens=8,
                       stop_token=stop))
    sch.submit(Request("next", d2, q2, max_new_tokens=4))
    res = sch.run()
    assert res["stopper"].stopped
    np.testing.assert_array_equal(res["next"].tokens, np.asarray(ref2))
    assert sch._allocator.free_pages == 4


def test_paged_scheduler_with_apb_prefill(key):
    """Admissions through the APB (augmented-layout, host-loop) prefill:
    the local-block doc cache pages into the pool like any dense cache."""
    from repro.core.splitting import make_layout
    cfg = get_config("granite-3-2b").reduced()
    model = model_lib.build(cfg)
    params = model.init(key)
    n, lq = 64, 8
    lay = make_layout(n, lq, 4, anchor_frac=cfg.anchor_frac,
                      passing_frac=cfg.passing_frac)
    dense = Engine(cfg, params, RunCtx(strategy="apb", layout=lay))
    paged = Engine(cfg, params, RunCtx(strategy="apb", layout=lay),
                   cache_layout="paged", page_size=16)
    r = np.random.default_rng(1)
    doc = jnp.asarray(r.integers(0, cfg.vocab_size, (1, n)), jnp.int32)
    query = jnp.asarray(r.integers(0, cfg.vocab_size, (1, lq)), jnp.int32)
    ref = dense.generate(doc, query, max_new_tokens=6).tokens[0]
    sch = Scheduler(paged, n_slots=2, decode_chunk=3)
    sch.submit(Request("apb", doc, query, max_new_tokens=6))
    res = sch.run()
    np.testing.assert_array_equal(res["apb"].tokens, np.asarray(ref))


def test_paged_scheduler_hybrid_ssm(key):
    """Hybrid attention+mamba: mamba states stay per-slot dense while
    attention pages through the pool; idle slots must not perturb it."""
    cfg, dense, paged = _mk_engines(key, "jamba-1.5-large-398b",
                                    page_size=16)
    doc, query = _mk_req(cfg, 32, 8, 5)
    ref = dense.generate(doc, query, max_new_tokens=6).tokens[0]
    sch = Scheduler(paged, n_slots=3, decode_chunk=4)   # 2 idle slots
    sch.submit(Request("solo", doc, query, max_new_tokens=6))
    res = sch.run()
    np.testing.assert_array_equal(res["solo"].tokens, np.asarray(ref))


# ---------------------------------------------------------------------------
# PageAllocator unit behaviour
# ---------------------------------------------------------------------------

def test_allocator_exhaustion_and_release():
    a = PageAllocator(4)
    r1 = a.reserve(3)
    assert sorted(r1) == [0, 1, 2] and a.free_pages == 1
    assert a.reserve(2) is None                  # exhausted: no partial take
    assert a.free_pages == 1                     # failed reserve takes nothing
    r2 = a.reserve(1)
    assert a.free_pages == 0
    a.release(r1)
    assert a.free_pages == 3
    with pytest.raises(ValueError, match="double release"):
        a.release(r1)
    a.release(r2)
    assert a.free_pages == 4


def test_allocator_churn_no_fragmentation():
    """Page-granular free lists cannot fragment: after arbitrary mixed
    retire/admit churn, any reservation <= free_pages succeeds and no
    page is ever issued twice concurrently."""
    rng = np.random.default_rng(0)
    a = PageAllocator(16)
    held = []
    for _ in range(200):
        if held and rng.random() < 0.45:
            a.release(held.pop(rng.integers(len(held))))
        else:
            n = int(rng.integers(1, 5))
            r = a.reserve(n)
            if r is None:
                assert a.free_pages < n          # only exhaustion defers
            else:
                held.append(r)
        live = [p for r in held for p in r]
        assert len(live) == len(set(live))       # no double issue
        assert len(live) + a.free_pages == 16    # conservation
    for r in held:
        a.release(r)
    assert a.free_pages == 16


def test_pages_for():
    assert pages_for(0, 8) == 1                  # empty still pins a page
    assert pages_for(1, 8) == 1
    assert pages_for(8, 8) == 1
    assert pages_for(9, 8) == 2
    assert pages_for(64, 16) == 4
    with pytest.raises(ValueError):
        pages_for(8, 0)
