"""Scheduling-policy API: SRPT/deadline decisions, the cost model, the
degeneration and preemption contracts, bucketed prefill batching, and
the AOT bucket warmup.

The exactness oracle is ``scheduling_policy="srpt"`` — the pre-policy
Scheduler behaviour.  The deadline policy must degenerate to it
bit-for-bit when no request carries an SLO (the ``scheduling_policy``
seam in ``analysis/static/oracle.py`` points here), and its preemption
machinery must conserve slots and pool pages.  Property-style invariants
run as seeded sweeps so they hold in environments without ``hypothesis``
(the randomized-trace analogues live in ``tests/test_properties.py``
style files, which importorskip it).
"""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import model as model_lib
from repro.models.transformer import RunCtx
from repro.serving import cache as cache_lib
from repro.serving import metrics as metrics_lib
from repro.serving.config import ServeConfig
from repro.serving.engine import Engine
from repro.serving.policy import (ActiveView, AdmissionView, CostModel,
                                  DeadlinePolicy, PendingView,
                                  QueueSnapshot, SchedulingPolicy,
                                  SrptPolicy, build_policy)
from repro.serving.scheduler import Request, Scheduler


def _mk_engine(key, arch="granite-3-2b", **kw):
    cfg = get_config(arch).reduced()
    if cfg.has_moe:
        cfg = dataclasses.replace(cfg, moe_capacity_factor=8.0)
    model = model_lib.build(cfg)
    params = model.init(key)
    return cfg, Engine(cfg, params, RunCtx(strategy="full"), **kw)


def _mk_req(cfg, n, lq, seed):
    r = np.random.default_rng(seed)
    return (jnp.asarray(r.integers(0, cfg.vocab_size, (1, n)), jnp.int32),
            jnp.asarray(r.integers(0, cfg.vocab_size, (1, lq)), jnp.int32))


# ---------------------------------------------------------------------------
# Schema / factory
# ---------------------------------------------------------------------------

def test_goodput_keys_in_sync_with_checker():
    """The stdlib-only mirror in tools/check_bench_results.py must stay
    identical to the source-of-truth tuple in repro.serving.metrics."""
    import importlib.util
    import os
    path = os.path.join(os.path.dirname(__file__), "..", "tools",
                        "check_bench_results.py")
    spec = importlib.util.spec_from_file_location("cbr", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    assert tuple(mod.GOODPUT_KEYS) == tuple(metrics_lib.GOODPUT_KEYS)


def test_build_policy_dispatch():
    assert isinstance(build_policy("srpt"), SrptPolicy)
    assert isinstance(build_policy("deadline"), DeadlinePolicy)
    assert isinstance(build_policy("srpt"), SchedulingPolicy)
    assert isinstance(build_policy("deadline"), SchedulingPolicy)
    with pytest.raises(ValueError, match="scheduling_policy"):
        build_policy("fifo")


def test_serve_config_policy_knobs():
    cfg = ServeConfig(scheduling_policy="deadline", prefill_chunk=8,
                      prefill_batch_max=4, aot_warmup=True)
    assert cfg.prefill_batch_max == 4
    with pytest.raises(ValueError, match="scheduling_policy"):
        ServeConfig(scheduling_policy="edf")
    with pytest.raises(ValueError, match="power of two"):
        ServeConfig(prefill_chunk=8, prefill_batch_max=3)
    with pytest.raises(ValueError, match="prefill_chunk"):
        ServeConfig(aot_warmup=True)          # warmup needs chunking
    with pytest.raises(ValueError, match="prefill_chunk"):
        ServeConfig(prefill_batch_max=2)      # batching needs chunking


# ---------------------------------------------------------------------------
# Cost model
# ---------------------------------------------------------------------------

def test_cost_model_ewma_and_extrapolation():
    cm = CostModel(alpha=0.5)
    assert cm.chunk_seconds(8) == 0.0          # cold: optimistic
    assert cm.decode_seconds(4) == 0.0
    cm.observe_prefill(8, 1.0)
    assert cm.chunk_seconds(8) == pytest.approx(1.0)
    cm.observe_prefill(8, 3.0)                 # EWMA, not replacement
    assert cm.chunk_seconds(8) == pytest.approx(2.0)
    # unmeasured buckets extrapolate linearly in tokens from the
    # nearest measured bucket
    assert cm.chunk_seconds(16) == pytest.approx(4.0)
    assert cm.chunk_seconds(4) == pytest.approx(1.0)
    cm.observe_decode(4, 0.4)
    assert cm.decode_seconds(8) == pytest.approx(0.8)
    # a full-document projection sums the chunk plan
    assert cm.prefill_seconds(24, 8) == pytest.approx(3 * 2.0)


# ---------------------------------------------------------------------------
# Policy decisions (unit, hand-built snapshots)
# ---------------------------------------------------------------------------

def _snap(stage="admission", **kw):
    kw.setdefault("now_s", 0.0)
    kw.setdefault("free_slots", 1)
    return QueueSnapshot(stage=stage, **kw)


def test_srpt_decisions():
    pol = SrptPolicy()
    pend = (PendingView("a", 64, 8, 4, order=0),
            PendingView("b", 16, 8, 4, order=1))
    act = pol.decide(_snap(pending=pend))
    assert act.admit == ("a", "b") and act.preempt is None   # FIFO
    adms = (AdmissionView("x", 0, chunks_left=3, doc_len=48, order=0),
            AdmissionView("y", 1, chunks_left=1, doc_len=16, order=1))
    act = pol.decide(_snap("prefill", admissions=adms, interleave=2))
    assert act.prefill == "y" and act.decode_chunks == 2   # SRPT
    act = pol.decide(_snap("prefill",
                           active=(ActiveView("z", 0, 4, 0.0),)))
    assert act.prefill is None and act.decode_chunks == 1


def test_deadline_edf_admission_and_resume_order():
    pol = DeadlinePolicy()
    pend = (PendingView("late", 16, 8, 4, order=0, arrival_s=0.0,
                        ttft_slo_s=9.0),
            PendingView("soon", 16, 8, 4, order=1, arrival_s=0.0,
                        ttft_slo_s=1.0),
            PendingView("none", 16, 8, 4, order=2))
    parked = (AdmissionView("p1", -1, 2, 32, order=3, ttft_slo_s=5.0),
              AdmissionView("p0", -1, 2, 32, order=4, ttft_slo_s=0.5))
    act = pol.decide(_snap(pending=pend, parked=parked))
    assert act.admit == ("soon", "late", "none")       # EDF, inf last
    assert act.resume == ("p0", "p1")


def test_deadline_preempts_only_laxer_inflight():
    pol = DeadlinePolicy()
    pend = (PendingView("hot", 16, 8, 4, order=5, arrival_s=0.0,
                        ttft_slo_s=1e-6),)
    long_adm = AdmissionView("long", 0, chunks_left=7, doc_len=64,
                             order=0, chunk_size=8)
    act = pol.decide(_snap(pending=pend, admissions=(long_adm,),
                           free_slots=0, default_chunk=8))
    assert act.preempt == "long"                  # laxer (inf deadline)
    # a free slot means no preemption is needed
    act = pol.decide(_snap(pending=pend, admissions=(long_adm,),
                           free_slots=1, default_chunk=8))
    assert act.preempt is None
    # no preemptible victim (batched group)
    grp = dataclasses.replace(long_adm, preemptible=False)
    act = pol.decide(_snap(pending=pend, admissions=(grp,),
                           free_slots=0, default_chunk=8))
    assert act.preempt is None
    # preemption cap reached: the victim is never parked again
    capped = dataclasses.replace(long_adm, preemptions=2)
    act = pol.decide(_snap(pending=pend, admissions=(capped,),
                           free_slots=0, default_chunk=8))
    assert act.preempt is None
    # an earlier-deadline in-flight admission is not a victim
    tight = dataclasses.replace(long_adm, ttft_slo_s=1e-9)
    act = pol.decide(_snap(pending=pend, admissions=(tight,),
                           free_slots=0, default_chunk=8))
    assert act.preempt is None


def test_deadline_chunk_size_shrinks_under_pressure():
    pol = DeadlinePolicy()
    for b, s in [(2, 0.01), (4, 0.02), (8, 0.04)]:
        pol.cost.observe_prefill(b, s)
    req = PendingView("big", 64, 8, 4, order=0)
    ladder = (2, 4, 8)
    # no SLOs anywhere: always the config default (degeneration)
    snap = _snap(default_chunk=8, bucket_ladder=ladder)
    assert pol.chunk_size(req, snap) == 8
    # a co-scheduled active request with a tight TPOT budget tolerates
    # only the smallest chunk stall
    act = (ActiveView("t", 0, 4, last_token_s=0.0, tpot_slo_s=0.012),)
    snap = _snap(default_chunk=8, bucket_ladder=ladder, active=act)
    assert pol.chunk_size(req, snap) == 2
    # a laxer budget admits a bigger chunk
    act = (ActiveView("t", 0, 4, last_token_s=0.0, tpot_slo_s=0.025),)
    snap = _snap(default_chunk=8, bucket_ladder=ladder, active=act)
    assert pol.chunk_size(req, snap) == 4


def test_deadline_interleave_reacts_to_tpot_risk():
    pol = DeadlinePolicy()
    pol.cost.observe_decode(4, 0.4)              # 0.1 s / step
    adm = (AdmissionView("a", 0, chunks_left=2, doc_len=16, order=0,
                         chunk_size=8),)
    # an active request one decode-chunk away from missing its TPOT SLO
    act = (ActiveView("t", 1, 4, last_token_s=0.0, tpot_slo_s=0.2),)
    snap = _snap("prefill", admissions=adm, active=act, interleave=1,
                 decode_chunk=4, now_s=0.0)
    assert pol.decide(snap).decode_chunks == 2   # boosted
    # no SLOs: the static interleave, untouched
    act0 = (ActiveView("t", 1, 4, last_token_s=0.0),)
    snap = _snap("prefill", admissions=adm, active=act0, interleave=1)
    assert pol.decide(snap).decode_chunks == 1


# ---------------------------------------------------------------------------
# Degeneration contract (seeded property sweep)
# ---------------------------------------------------------------------------

def test_deadline_no_slo_decisions_match_srpt():
    """Property: on ANY snapshot with no SLOs set, the deadline policy's
    decision equals SRPT's — both stages, including chunk_size."""
    rng = np.random.default_rng(0)
    srpt, ddl = SrptPolicy(), DeadlinePolicy()
    # a warmed cost model must not change the degenerate decisions
    ddl.cost.observe_prefill(8, 0.02)
    ddl.cost.observe_decode(4, 0.01)
    for trial in range(200):
        n_p, n_a, n_k, n_x = rng.integers(0, 4, size=4)
        pend = tuple(
            PendingView(f"p{i}", int(rng.integers(1, 100)), 8,
                        int(rng.integers(1, 16)), order=i)
            for i in range(n_p))
        adms = tuple(
            AdmissionView(f"a{i}", i, int(rng.integers(1, 9)),
                          int(rng.integers(1, 100)), order=10 + i,
                          chunk_size=8)
            for i in range(n_a))
        parked = tuple(
            AdmissionView(f"k{i}", -1, int(rng.integers(1, 9)),
                          int(rng.integers(1, 100)), order=20 + i)
            for i in range(n_k))
        act = tuple(
            ActiveView(f"x{i}", 8 + i, int(rng.integers(1, 8)),
                       float(rng.random()))
            for i in range(n_x))
        for stage in ("admission", "prefill"):
            snap = _snap(stage, pending=pend, admissions=adms,
                         parked=parked, active=act,
                         free_slots=int(rng.integers(0, 3)),
                         default_chunk=8, interleave=1,
                         bucket_ladder=(2, 4, 8),
                         now_s=float(rng.random()))
            assert ddl.decide(snap) == srpt.decide(snap), (trial, stage)
            for p in pend:
                assert ddl.chunk_size(p, snap) == srpt.chunk_size(p, snap)


# ---------------------------------------------------------------------------
# Bucket ladder / chunk-plan coverage (seeded property sweep)
# ---------------------------------------------------------------------------

def test_bucket_ladder_is_pow2_and_bounded():
    assert cache_lib.bucket_ladder(16) == (2, 4, 8, 16)
    assert cache_lib.bucket_ladder(16, 4) == (4, 8, 16)
    for cs in (1, 2, 8, 64):
        ladder = cache_lib.bucket_ladder(cs)
        assert ladder and ladder[-1] == cs
        assert all(b & (b - 1) == 0 for b in ladder)


def test_chunk_plans_cover_doc_and_warm_lens():
    """Property: a chunk plan covers exactly the document (contiguous,
    no overlap, no gap), every chunk length is a power of two <=
    chunk_size, and every length appears in the warmup set {pow2 p <=
    min(cap, chunk_size)} — the zero-recompile warmup contract."""
    rng = np.random.default_rng(1)
    for _ in range(300):
        n = int(rng.integers(1, 200))
        cs = int(2 ** rng.integers(0, 7))
        plan = cache_lib.chunk_plan(n, cs)
        offs, lens = zip(*plan)
        assert sum(lens) == n
        assert offs == tuple(np.cumsum((0,) + lens[:-1]))
        assert all(t & (t - 1) == 0 and t <= cs for t in lens)
        cap = int(rng.integers(n, 2 * n + 1))       # any capacity >= n
        warm = {p for p in (2 ** k for k in range(12))
                if p <= min(cap, cs)}
        assert set(lens) <= warm, (n, cs, cap)


# ---------------------------------------------------------------------------
# Degeneration: bit-exact tokens across archs
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch,chunk", [("granite-3-2b", 8),
                                        ("jamba-1.5-large-398b", None)])
def test_deadline_without_slos_matches_srpt_tokens(arch, chunk, key):
    """With no SLOs set, the deadline policy serves greedy tokens
    bit-identical to the SRPT oracle — attention-only chunked and
    hybrid-mamba monolithic admissions alike."""
    cfg, eng = _mk_engine(key, arch)
    reqs = [(f"r{i}", *_mk_req(cfg, n, lq, i), new)
            for i, (n, lq, new) in enumerate(
                [(48, 8, 6), (16, 4, 4), (32, 8, 5)])]
    outs = {}
    for pol in ("srpt", "deadline"):
        sch = Scheduler(eng, config=ServeConfig(
            n_slots=2, decode_chunk=3, prefill_chunk=chunk,
            scheduling_policy=pol))
        for rid, d, q, new in reqs:
            sch.submit(Request(rid, d, q, max_new_tokens=new))
        outs[pol] = sch.run()
        assert sch.preemptions == 0            # nothing to preempt for
    for rid, _, _, _ in reqs:
        np.testing.assert_array_equal(outs["srpt"][rid].tokens,
                                      outs["deadline"][rid].tokens)


# ---------------------------------------------------------------------------
# Preemption: conserves slots + pages, starvation-free
# ---------------------------------------------------------------------------

def _paged_sched(eng, **kw):
    return Scheduler(eng, config=ServeConfig(
        cache_layout="paged", page_size=8, scheduling_policy="deadline",
        **kw))


def test_preemption_conserves_slots_and_pages(key):
    """A deadline-critical short preempts the in-flight long at a chunk
    boundary; the long keeps its pages while parked, resumes, and both
    serve their solo-oracle tokens; every page returns to the pool."""
    cfg, eng = _mk_engine(key, config=ServeConfig(cache_layout="paged",
                                                  page_size=8))
    d1, q1 = _mk_req(cfg, 64, 8, 1)
    d2, q2 = _mk_req(cfg, 16, 4, 2)
    ref1 = eng.generate(d1, q1, max_new_tokens=6).tokens[0]
    ref2 = eng.generate(d2, q2, max_new_tokens=4).tokens[0]
    sch = _paged_sched(eng, n_slots=1, decode_chunk=2, prefill_chunk=8,
                       num_pages=10, doc_capacity=64,
                       tail_capacity=16)
    sch.submit(Request("long", d1, q1, max_new_tokens=6))
    sch.begin()
    sch.step()                                  # long admitted, 1 chunk
    assert len(sch.admissions) == 1
    used_before = sch._allocator.free_pages
    sch.submit(Request("short", d2, q2, max_new_tokens=4,
                       ttft_slo_s=1e-6))        # already past deadline
    sch.step()                                  # preempt long, admit short
    assert sch.preemptions == 1
    assert "long" in sch._parked
    # the preemption contract: the parked long HOLDS its pages (no
    # re-reservation on resume), only its slot was released
    assert len(sch.admissions) + len(sch.active) <= sch.n_slots
    assert sch._allocator.free_pages == used_before - cache_lib.pages_for(
        16, sch.engine.page_size)
    while sch.has_work:
        sch.step()
    res = sch.results
    np.testing.assert_array_equal(res["long"].tokens, np.asarray(ref1))
    np.testing.assert_array_equal(res["short"].tokens, np.asarray(ref2))
    assert res["long"].preemptions == 1
    assert res["short"].preemptions == 0
    assert sch._allocator.free_pages == sch.num_pages   # all released


def test_preempted_long_is_starvation_free(key):
    """A stream of deadline-critical shorts may park the long at most
    ``max_preemptions`` times; parked admissions resume ahead of new
    admits, so the long always completes."""
    cfg, eng = _mk_engine(key, config=ServeConfig(cache_layout="paged",
                                                  page_size=8))
    d1, q1 = _mk_req(cfg, 64, 8, 1)
    ref1 = eng.generate(d1, q1, max_new_tokens=4).tokens[0]
    shorts = [(f"s{i}", *_mk_req(cfg, 16, 4, 10 + i)) for i in range(4)]
    refs = {rid: eng.generate(d, q, max_new_tokens=2).tokens[0]
            for rid, d, q in shorts}
    sch = _paged_sched(eng, n_slots=1, decode_chunk=2, prefill_chunk=8,
                       num_pages=12, doc_capacity=64, tail_capacity=16)
    sch.submit(Request("long", d1, q1, max_new_tokens=4))
    sch.begin()
    sch.step()
    for rid, d, q in shorts:                   # arrive mid-prefill
        sch.submit(Request(rid, d, q, max_new_tokens=2, ttft_slo_s=1e-6))
        sch.step()
    while sch.has_work:
        sch.step()
    res = sch.results
    assert set(res) == {"long", "s0", "s1", "s2", "s3"}
    assert res["long"].preemptions <= 2        # DeadlinePolicy default cap
    np.testing.assert_array_equal(res["long"].tokens, np.asarray(ref1))
    for rid, _, _ in shorts:
        np.testing.assert_array_equal(res[rid].tokens,
                                      np.asarray(refs[rid]))
    assert sch._allocator.free_pages == sch.num_pages


# ---------------------------------------------------------------------------
# Batched prefill: bit-exact vs singleton admissions
# ---------------------------------------------------------------------------

def test_batched_prefill_matches_sequential(key):
    """Batch-concat admission groups must serve the same greedy tokens
    as singleton admissions of the same requests."""
    cfg, eng = _mk_engine(key)
    reqs = [(f"r{i}", *_mk_req(cfg, 13, 8, 20 + i)) for i in range(4)]
    outs = {}
    for batch_max in (1, 4):
        sch = Scheduler(eng, config=ServeConfig(
            n_slots=4, decode_chunk=3, prefill_chunk=8,
            prefill_batch_max=batch_max))
        for rid, d, q in reqs:
            sch.submit(Request(rid, d, q, max_new_tokens=4))
        outs[batch_max] = sch.run()
    for rid, _, _ in reqs:
        np.testing.assert_array_equal(outs[1][rid].tokens,
                                      outs[4][rid].tokens)
    # the grouped run really batched: a batch-4 chunk signature ran
    assert any(kind == "chunk" and b == 4
               for kind, b, t, cap, paged in eng.prefill_shapes)
    assert all(outs[4][rid].prefill_bucket == cache_lib.pow2_bucket(13)
               for rid, _, _ in reqs)


def test_batched_prefill_groups_snap_to_pow2(key):
    """3 batchable shorts: the group snaps down to 2, the leftover
    admits as a singleton — tokens identical to singleton serving."""
    cfg, eng = _mk_engine(key)
    reqs = [(f"r{i}", *_mk_req(cfg, 16, 8, 30 + i)) for i in range(3)]
    outs = {}
    for batch_max in (1, 4):
        sch = Scheduler(eng, config=ServeConfig(
            n_slots=4, decode_chunk=3, prefill_chunk=8,
            prefill_batch_max=batch_max))
        for rid, d, q in reqs:
            sch.submit(Request(rid, d, q, max_new_tokens=4))
        outs[batch_max] = sch.run()
    for rid, _, _ in reqs:
        np.testing.assert_array_equal(outs[1][rid].tokens,
                                      outs[4][rid].tokens)
    assert any(kind == "chunk" and b == 2
               for kind, b, t, cap, paged in eng.prefill_shapes)


# ---------------------------------------------------------------------------
# AOT bucket warmup: once per scheduler, zero recompiles after
# ---------------------------------------------------------------------------

def test_warmup_once_and_zero_new_shapes(key):
    """``warm()`` runs the per-bucket warmup exactly once (not per
    admission) and covers every prefill shape the run produces — the
    compile-count probe that pins the zero-recompile contract."""
    cfg, eng = _mk_engine(key, config=ServeConfig(cache_layout="paged",
                                                  page_size=8))
    sch = _paged_sched(eng, n_slots=2, decode_chunk=2, prefill_chunk=8,
                       num_pages=16, aot_warmup=True)
    # mixed lengths incl. a non-pow2 doc whose plan mixes ladder rungs
    for i, n in enumerate([13, 16, 24]):
        d, q = _mk_req(cfg, n, 8, 40 + i)
        sch.submit(Request(f"r{i}", d, q, max_new_tokens=3))
    sch.begin()                                # aot_warmup fires here
    assert eng.prefill_warmups == 1
    shapes_after_warm = set(eng.prefill_shapes)
    while sch.has_work:
        sch.step()
    assert eng.prefill_warmups == 1            # never re-warmed
    assert set(eng.prefill_shapes) == shapes_after_warm   # 0 recompiles
    # a second cycle through the same scheduler stays warm too
    d, q = _mk_req(cfg, 13, 8, 50)
    sch.submit(Request("again", d, q, max_new_tokens=3))
    sch.run()
    assert eng.prefill_warmups == 1
    assert set(eng.prefill_shapes) == shapes_after_warm


# ---------------------------------------------------------------------------
# Result metrics / shared schema
# ---------------------------------------------------------------------------

def test_result_slo_fields_and_metrics_schema(key):
    cfg, eng = _mk_engine(key)
    d, q = _mk_req(cfg, 16, 4, 3)
    sch = Scheduler(eng, config=ServeConfig(n_slots=1, decode_chunk=2,
                                            prefill_chunk=8))
    sch.submit(Request("slo", d, q, max_new_tokens=4, ttft_slo_s=60.0,
                       tpot_slo_s=60.0))
    sch.submit(Request("free", d, q, max_new_tokens=4))
    results = sch.run()
    r = results["slo"]
    assert r.deadline_s == pytest.approx(60.0)
    assert r.ttft_slo_met is True              # a minute is generous
    assert r.tpot_p99_s >= 0.0 and r.preemptions == 0
    f = results["free"]
    assert f.deadline_s is None and f.ttft_slo_met is None
    assert metrics_lib.slo_met(r) and metrics_lib.slo_met(f)
    rec = metrics_lib.result_record(r)
    assert rec["rid"] == "slo" and rec["slo_met"] is True
    agg = metrics_lib.aggregate(results, wall_s=1.0)
    for k in metrics_lib.GOODPUT_KEYS:
        assert k in agg
    assert agg["requests"] == 2
    assert agg["slo_attainment"] == pytest.approx(1.0)
    assert agg["goodput_per_s"] == pytest.approx(2.0)


def test_submit_validates_slo_fields(key):
    cfg, eng = _mk_engine(key)
    d, q = _mk_req(cfg, 16, 4, 4)
    sch = Scheduler(eng, config=ServeConfig(n_slots=1))
    with pytest.raises(ValueError, match="ttft_slo_s"):
        sch.submit(Request("bad", d, q, max_new_tokens=2,
                           ttft_slo_s=0.0))
    with pytest.raises(ValueError, match="tpot_slo_s"):
        sch.submit(Request("bad", d, q, max_new_tokens=2,
                           tpot_slo_s=-1.0))
    with pytest.raises(ValueError, match="arrival_s"):
        sch.submit(Request("bad", d, q, max_new_tokens=2,
                           arrival_s=-0.5))


def test_scheduler_accepts_policy_object(key):
    """A runtime policy object overrides config.scheduling_policy — the
    pluggable seam for out-of-tree policies."""
    cfg, eng = _mk_engine(key)
    d, q = _mk_req(cfg, 16, 4, 5)
    ref = eng.generate(d, q, max_new_tokens=4).tokens[0]

    class CountingSrpt(SrptPolicy):
        name = "counting"

        def __init__(self):
            self.calls = 0

        def decide(self, snap):
            self.calls += 1
            return super().decide(snap)

    pol = CountingSrpt()
    sch = Scheduler(eng, config=ServeConfig(n_slots=1, decode_chunk=2),
                    policy=pol)
    assert sch.policy is pol
    sch.submit(Request("a", d, q, max_new_tokens=4))
    res = sch.run()
    np.testing.assert_array_equal(res["a"].tokens, np.asarray(ref))
    assert pol.calls > 0
