"""Static-analysis suite (repro.analysis.static + tools/repro_lint).

Two obligations, tested separately:

* each analyzer *catches its seeded-bad fixture* — a deliberately
  out-of-bounds BlockSpec, a spec/shape mismatch, each tracing hazard,
  an oracle seam whose evidence was stripped — so the rules cannot
  silently stop firing; and
* the *real tree runs clean* — every remaining finding is covered by an
  in-source suppression with a rationale — which is the invariant the
  CI static-analysis job enforces.

Suppression mechanics (comment parsing, rationale requirement SUP002,
staleness SUP001 and its partial-run restriction) are unit-tested here
too, since the whole gate leans on them.
"""
import shutil
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest
from jax.sharding import PartitionSpec as P

from repro.analysis.static import bounds, oracle, shardspec, tracelint
from repro.analysis.static import findings as fnd
from repro.kernels import (BlockOperand, KernelGridAnalysis, ScalarSpec,
                           kernel_analyses)

ROOT = Path(__file__).resolve().parent.parent


def _rules(findings):
    return sorted(f.rule for f in findings)


# ---------------------------------------------------------------------------
# findings + suppressions
# ---------------------------------------------------------------------------

def test_parse_suppressions_comment_only():
    text = textwrap.dedent('''
        """Docs may quote the syntax:

            # repro-lint: disable=TRC001 -- quoted example, not live
        """
        x = 1  # repro-lint: disable=SHD010 -- real comment
        # repro-lint: disable=PB001,PB002 -- standalone, two rules
        y = 2
    ''')
    sups = fnd.parse_suppressions(text, "m.py")
    # the docstring example must NOT register — only real COMMENT tokens
    assert [(s.rules, s.rationale) for s in sups] == [
        (("SHD010",), "real comment"),
        (("PB001", "PB002"), "standalone, two rules"),
    ]


def test_apply_suppressions_line_and_line_above():
    f_same = fnd.Finding("TRC001", "m.py", 5, "x")
    f_above = fnd.Finding("TRC002", "m.py", 9, "y")
    f_miss = fnd.Finding("TRC001", "m.py", 20, "z")
    sups = [fnd.Suppression("m.py", 5, ("TRC001",), "why"),
            fnd.Suppression("m.py", 8, ("TRC002",), "why")]
    unsup, sup, used = fnd.apply_suppressions(
        [f_same, f_above, f_miss], sups)
    assert sup == [f_same, f_above]
    assert unsup == [f_miss]
    assert used == {("m.py", 5), ("m.py", 8)}


def test_suppression_without_rationale_is_sup002():
    f = fnd.Finding("TRC001", "m.py", 3, "x")
    sups = [fnd.Suppression("m.py", 3, ("TRC001",), "")]
    unsup, sup, used = fnd.apply_suppressions([f], sups)
    assert sup == [] and used == set()
    assert _rules(unsup) == ["SUP002", "TRC001"]


def test_stale_suppression_flagged_only_for_ran_analyzers():
    sups = [fnd.Suppression("m.py", 3, ("TRC001",), "why"),
            fnd.Suppression("m.py", 7, ("SHD010",), "why")]
    # nothing matched either; only the TRC analyzer "ran"
    stale = fnd.stale_suppressions(sups, set(), {"TRC"})
    assert _rules(stale) == ["SUP001"]
    assert stale[0].line == 3
    # both analyzers ran -> both stale
    stale = fnd.stale_suppressions(sups, set(), {"TRC", "SHD"})
    assert _rules(stale) == ["SUP001", "SUP001"]
    # a used site is never stale
    stale = fnd.stale_suppressions(sups, {("m.py", 3)}, {"TRC", "SHD"})
    assert [s.line for s in stale] == [7]


# ---------------------------------------------------------------------------
# bounds checker (PB)
# ---------------------------------------------------------------------------

def _toy(index_map, shape=(4, 8), block=(2, 4), grid=(2, 2), scalars=()):
    return KernelGridAnalysis(
        kernel="toy", case="fixture", source="x.py", grid=grid,
        scalars=scalars,
        operands=(BlockOperand("q", shape, block, index_map),))


def test_bounds_in_bounds_map_is_clean():
    assert bounds.check_analysis(_toy(lambda i, j: (i, j))) == []


def test_bounds_rejects_oob_blockspec():
    out = bounds.check_analysis(_toy(lambda i, j: (i + 1, j)))
    assert _rules(out) == ["PB001"]
    assert "outside" in out[0].message


def test_bounds_scalar_at_hi_pushes_window_out():
    # guarded scalar, but the declared hi (3) * block exceeds the dim:
    # the lo/hi double fill must catch it even though lo (0) is fine
    pt = ScalarSpec("pt", (4,), lo=0, hi=3, guard="clip")
    out = bounds.check_analysis(
        _toy(lambda i, j, pt: (pt[i], j), scalars=(pt,)))
    assert "PB001" in _rules(out)


def test_bounds_unguarded_scalar_read_is_pb002():
    pt = ScalarSpec("pt", (4,), lo=0, hi=1, guard="")
    out = bounds.check_analysis(
        _toy(lambda i, j, pt: (pt[i], j), scalars=(pt,)))
    assert _rules(out) == ["PB002"]
    # same map with a declared guard is clean
    pt_g = ScalarSpec("pt", (4,), lo=0, hi=1, guard="jnp.clip in wrapper")
    assert bounds.check_analysis(
        _toy(lambda i, j, pt: (pt[i], j), scalars=(pt_g,))) == []


def test_bounds_rank_mismatch_is_pb003():
    out = bounds.check_analysis(_toy(lambda i, j: (i,), block=(2,)))
    assert _rules(out) == ["PB003"]


def test_bounds_huge_grid_is_rejected_not_enumerated():
    out = bounds.check_analysis(_toy(lambda i, j: (i, j),
                                     grid=(500, 500)))
    assert _rules(out) == ["PB003"]
    assert str(bounds.MAX_GRID_POINTS) in out[0].message


def test_registry_populated_and_real_kernels_prove_clean():
    analyses = kernel_analyses()
    assert set(analyses) == {"apb_attention", "paged_attention"}
    for name, cases in analyses.items():
        assert len(cases) >= 8, name          # a real config matrix
    assert bounds.run(ROOT) == []


# ---------------------------------------------------------------------------
# sharding-spec verifier (SHD)
# ---------------------------------------------------------------------------

MESH = {"data": 2, "model": 4}


def test_spec_rank_exceeds_leaf_rank():
    out = shardspec.check_spec("b", P("data", None, "model"), (8, 4),
                               MESH, "s.py", 1)
    assert _rules(out) == ["SHD001"]


def test_spec_unknown_mesh_axis():
    out = shardspec.check_spec("b", P("pod2"), (8,), MESH, "s.py", 1)
    assert _rules(out) == ["SHD002"]


def test_spec_indivisible_dim():
    out = shardspec.check_spec("b", P("model"), (6,), MESH, "s.py", 1)
    assert _rules(out) == ["SHD003"]


def test_spec_tuple_axes_divisible_is_clean():
    assert shardspec.check_spec("b", P(("data", "model"), None),
                                (8, 3), MESH, "s.py", 1) == []


def test_check_rep_false_fixture_flagged(tmp_path):
    (tmp_path / "m.py").write_text(textwrap.dedent("""
        from repro.parallel import collectives
        fn = collectives.shard_map(f, mesh=m, in_specs=a,
                                   out_specs=b, check_rep=False)
    """))
    out = shardspec._check_rep_findings(tmp_path, ["m.py"])
    assert _rules(out) == ["SHD010"]


def test_real_builders_match_real_constructors():
    """Every PartitionSpec builder vs eval_shape of the constructor it
    places — the drift this analyzer exists to catch."""
    cases = shardspec.spec_cases(MESH)
    assert len(cases) > 20                    # caches + topk + params
    for builder, spec, shape in cases:
        assert shardspec.check_spec(builder, spec, shape, MESH,
                                    "s.py", 0) == []


def test_real_tree_shd_findings_all_suppressed():
    out = shardspec.run(ROOT)
    assert _rules(out).count("SHD010") == len(out)   # only audited sites
    sups = fnd.collect_suppressions(
        ROOT, fnd.source_files(ROOT, ("src", "tools", "tests")))
    unsup, sup, _ = fnd.apply_suppressions(out, sups)
    assert unsup == []
    assert len(sup) == 3                      # decode/strategies/engine


# ---------------------------------------------------------------------------
# tracing-hazard linter (TRC)
# ---------------------------------------------------------------------------

def _lint(src):
    return tracelint.lint_source(textwrap.dedent(src), "m.py")


@pytest.mark.parametrize("rule,src", [
    ("TRC001", "def f(x):\n    return int(jnp.sum(x))\n"),
    ("TRC002", "def f(x):\n    if jnp.any(x > 0):\n        return 1\n"),
    ("TRC002", "def f(x):\n    while jnp.max(x) < 9:\n        x = x + 1\n"),
    ("TRC003", "import jax.numpy as jnp\nSCALE = jnp.ones((4,))\n"),
    ("TRC003", "class C:\n    TAB = jax.numpy.arange(8)\n"),
    ("TRC004", "import jax\n"
               "def _f(x, opts=[1]):\n    return x\n"
               "f = jax.jit(_f, static_argnames=('opts',))\n"),
    ("TRC005", "import jax\n"
               "class E:\n"
               "    def __init__(self):\n"
               "        self.step = jax.jit(self._step,\n"
               "                            donate_argnums=(1,))\n"
               "    def go(self):\n"
               "        y = self.step(self.p, self.caches)\n"
               "        return y\n"),
    ("TRC006", "def f(k, o):\n"
               "    return pl.pallas_call(k, out_shape=o)(1)\n"),
])
def test_tracelint_catches_seeded_hazard(rule, src):
    assert rule in _rules(_lint(src))


def test_tracelint_clean_counterparts():
    # rebinding the donated arg satisfies TRC005
    assert _lint("""
        import jax
        class E:
            def __init__(self):
                self.step = jax.jit(self._step, donate_argnums=(1,))
            def go(self):
                self.p, self.caches = self.step(self.p, self.caches)
    """) == []
    # interpret= plumbing satisfies TRC006
    assert _lint("def f(k, o, flag):\n"
                 "    return pl.pallas_call(k, out_shape=o,"
                 " interpret=flag)(1)\n") == []
    # jnp inside a function is not import-time (no TRC003)
    assert _lint("import jax.numpy as jnp\n"
                 "def f():\n    return jnp.ones((4,))\n") == []


def test_tracelint_static_dtype_predicates_not_traced():
    # jnp.issubdtype is host-side metadata, not traced computation —
    # regression for a transformer.embed false positive
    assert _lint("""
        def f(x):
            if jnp.issubdtype(x.dtype, jnp.integer):
                return x
            return x + 1
    """) == []


def test_tracelint_donation_matches_attribute_rebind():
    # Load-vs-Store ctx on self.X must not defeat the rebind match —
    # regression for 10 engine.py false positives
    out = _lint("""
        import jax
        class E:
            def __init__(self):
                self.step = jax.jit(self._step, donate_argnums=(1, 2))
            def go(self):
                self.a, self.b = self.step(self.p, self.a, self.b)
    """)
    assert out == []


def test_real_tree_trc_findings_all_suppressed():
    out = tracelint.run(ROOT)
    sups = fnd.collect_suppressions(
        ROOT, fnd.source_files(ROOT, ("src", "tools", "tests")))
    unsup, sup, _ = fnd.apply_suppressions(out, sups)
    assert unsup == []
    assert {f.rule for f in sup} == {"TRC001", "TRC002"}   # engine stop check


# ---------------------------------------------------------------------------
# oracle-coverage enforcer (ORA)
# ---------------------------------------------------------------------------

def test_real_tree_oracle_chain_intact():
    assert oracle.run(ROOT) == []


def _seam_tree(tmp_path):
    """Copy exactly the files the SEAMS registry references."""
    paths = {s.dispatch_path for s in oracle.SEAMS}
    paths |= {e.path for s in oracle.SEAMS for e in s.evidence}
    for rel in paths:
        dst = tmp_path / rel
        dst.parent.mkdir(parents=True, exist_ok=True)
        shutil.copy(ROOT / rel, dst)
    return tmp_path


def test_removing_an_oracle_test_fails_enforcement(tmp_path):
    tree = _seam_tree(tmp_path)
    assert oracle.run(tree) == []             # copy is self-consistent
    t = tree / "tests/test_paged_cache.py"
    t.write_text(t.read_text().replace(
        'IMPLS = ["kernel", "gather"]', 'IMPLS = ["kernel"]'))
    out = oracle.run(tree)
    assert _rules(out) == ["ORA001"]
    assert "paged_impl" in out[0].message


def test_refactored_seam_goes_stale_loudly(tmp_path):
    tree = _seam_tree(tmp_path)
    d = tree / "src/repro/core/decode.py"
    d.write_text(d.read_text().replace('if impl == "kernel":',
                                       'if impl == "fused":'))
    out = oracle.run(tree)
    assert "ORA002" in _rules(out)
    assert any("paged_impl" in f.message for f in out
               if f.rule == "ORA002")


def test_missing_evidence_file_is_ora003(tmp_path):
    tree = _seam_tree(tmp_path)
    (tree / "tests/test_serving.py").unlink()
    out = oracle.run(tree)
    assert "ORA003" in _rules(out)


# ---------------------------------------------------------------------------
# driver CLI (subprocess; --oracle only, so no jax import in the child)
# ---------------------------------------------------------------------------

def _run_lint(*argv, cwd=ROOT):
    return subprocess.run(
        [sys.executable, "-m", "tools.repro_lint", *argv],
        cwd=cwd, capture_output=True, text=True, timeout=120)


def test_cli_oracle_ok_on_repo():
    r = _run_lint("--oracle")
    assert r.returncode == 0, r.stdout + r.stderr
    assert "repro_lint: ok" in r.stdout


def test_cli_fails_with_findings_on_broken_root(tmp_path):
    tree = _seam_tree(tmp_path)
    (tmp_path / "tests/test_serving.py").unlink()
    r = _run_lint("--oracle", "--root", str(tree))
    assert r.returncode == 1
    assert "FAIL" in r.stdout and "ORA003" in r.stdout


def test_cli_requires_analyzer_selection():
    r = _run_lint()
    assert r.returncode == 2                  # argparse error
