"""Retaining-head compressor: selection semantics + training recipe."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import compressor as comp


def test_select_topk_order_and_content(key):
    B, L, KV, D = 2, 32, 2, 16
    scores = jax.random.normal(key, (B, L, KV))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, L, KV, D))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, L, KV, D))
    ks, vs, idx = comp.select_topk(scores, k, v, 8)
    assert ks.shape == (B, 8, KV, D) and idx.shape == (B, 8, KV)
    # indices sorted (position-monotonic compressed block)
    assert bool(jnp.all(idx[:, 1:] >= idx[:, :-1]))
    # content matches gather
    for b in range(B):
        for h in range(KV):
            np.testing.assert_allclose(ks[b, :, h], k[b, idx[b, :, h], h])
    # the selected set is exactly the top-8 by score
    top = jnp.sort(jnp.argsort(scores, axis=1)[:, -8:, :], axis=1)
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(top))


def test_select_topk_clamps_oversized_budget(key):
    """lp > L (tiny local block, large passing budget) must select every
    unit instead of tripping lax.top_k — regression for the unguarded
    ``top_k(..., lp)`` call."""
    B, L, KV, D = 2, 6, 2, 8
    scores = jax.random.normal(key, (B, L, KV))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, L, KV, D))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, L, KV, D))
    ks, vs, idx = comp.select_topk(scores, k, v, 4 * L)
    # saturates at the block: all L units, in position order
    assert ks.shape == (B, L, KV, D) and idx.shape == (B, L, KV)
    np.testing.assert_array_equal(
        np.asarray(idx),
        np.broadcast_to(np.arange(L)[None, :, None], (B, L, KV)))
    np.testing.assert_allclose(np.asarray(ks), np.asarray(k))
    np.testing.assert_allclose(np.asarray(vs), np.asarray(v))
    # identical to an exactly-sized budget
    ks_eq, vs_eq, idx_eq = comp.select_topk(scores, k, v, L)
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(idx_eq))


def test_hostloop_saturated_passing_budget(key):
    """A hand-built layout with lp > lb must behave exactly like lp == lb
    (the selection saturates; no zero-key padding enters the pass
    region)."""
    from repro.core import reference
    from repro.core.splitting import APBLayout

    B, HOSTS, LB, H, KV, D = 1, 4, 8, 2, 2, 16
    din = (H + 2 * KV) * D
    retain = {"w1": jax.random.normal(key, (din, 8)) * 0.1,
              "b1": jnp.zeros((8,)),
              "w2": jax.random.normal(jax.random.fold_in(key, 1),
                                      (8, KV)) * 0.1,
              "b2": jnp.zeros((KV,))}
    kq = jax.random.split(jax.random.fold_in(key, 2), 3)
    lay_over = APBLayout(n_doc=LB * HOSTS, lq=2, n_hosts=HOSTS, lb=LB,
                         la_doc=2, lp=3 * LB)
    lay_exact = APBLayout(n_doc=LB * HOSTS, lq=2, n_hosts=HOSTS, lb=LB,
                          la_doc=2, lp=LB)
    q = jax.random.normal(kq[0], (B, lay_over.aug_len, H, D))
    k = jax.random.normal(kq[1], (B, lay_over.aug_len, KV, D))
    v = jax.random.normal(kq[2], (B, lay_over.aug_len, KV, D))
    out_over, _, _ = reference.apb_attention_hostloop(
        q, k, v, retain, lay_over, strategy="apb")
    out_exact, _, _ = reference.apb_attention_hostloop(
        q, k, v, retain, lay_exact, strategy="apb")
    np.testing.assert_allclose(np.asarray(out_over), np.asarray(out_exact),
                               atol=1e-6, rtol=1e-6)


def test_oracle_scores_find_needle(key):
    """A key present in both query and cache must receive high mass."""
    B, LQ, L, H, KV, D = 1, 4, 64, 4, 2, 16
    kc = jax.random.normal(key, (B, L, KV, D))
    q = jax.random.normal(jax.random.fold_in(key, 1), (B, LQ, H, D)) * 0.1
    needle = 17
    q = q.at[:, :, :, :].add(jnp.sqrt(float(D)) * kc[:, needle][:, None].repeat(LQ, 1).repeat(2, 2))
    s = comp.oracle_scores(q, kc)
    assert int(jnp.argmax(s.sum(-1), axis=1)[0]) == needle


def test_compressor_training_reduces_loss(key, rng):
    from repro.data import synthetic
    from repro.models import model as model_lib
    from repro.training import train_compressor as tc
    cfg = get_config("granite-3-2b").reduced()
    model = model_lib.build(cfg)
    params = model.init(key)

    def gen():
        while True:
            d, q, a = synthetic.batch_samples(rng, "passkey", 2, 56, 8,
                                              cfg.vocab_size)
            yield np.concatenate([d, q], 1)

    it = gen()
    tokens0 = jnp.asarray(next(it))
    retain = tc.extract_retain(params, cfg)
    captured = tc.capture_qkv(params, cfg, tokens0, jnp.arange(64)[None])
    labels = tc.importance_labels(captured, 8)
    loss0 = float(tc.compressor_loss(retain, captured, labels, 8))

    params2, loss_end = tc.train_compressor(params, cfg, it, steps=25,
                                            lq=8, log_every=0)
    assert loss_end < loss0 * 0.8, (loss0, loss_end)
