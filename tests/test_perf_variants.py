"""The §Perf optimized lowerings must be exact vs their baselines:
decomposed APB attention == monolithic reference; local-routed MoE ==
reference MoE (at non-dropping capacity)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops


@pytest.mark.parametrize("av,pv,window", [
    (24, 8, 0), (0, 0, 0), (24, 0, 0), (24, 16, 0), (24, 16, 8)])
def test_decomposed_matches_reference(key, av, pv, window):
    B, H, KV, D = 2, 4, 2, 32
    la, pcap, lb = 24, 16, 40
    ks = jax.random.split(key, 8)
    shapes = [(B, la, H, D), (B, lb, H, D), (B, la, KV, D),
              (B, pcap, KV, D), (B, lb, KV, D), (B, la, KV, D),
              (B, pcap, KV, D), (B, lb, KV, D)]
    args = [jax.random.normal(k_, s) for k_, s in zip(ks, shapes)]
    od = ops.apb_attention(*args, anchor_valid=av, pass_valid=pv,
                           window=window, use_kernel="decomposed")
    orf = ops.apb_attention(*args, anchor_valid=av, pass_valid=pv,
                            window=window, use_kernel=False)
    for a, b in zip(od, orf):
        if a.shape[1] == 0:
            continue
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=2e-5, rtol=2e-5)


def test_decomposed_softcap(key):
    B, H, KV, D = 1, 2, 2, 16
    la, pcap, lb = 8, 8, 16
    ks = jax.random.split(key, 8)
    shapes = [(B, la, H, D), (B, lb, H, D), (B, la, KV, D),
              (B, pcap, KV, D), (B, lb, KV, D), (B, la, KV, D),
              (B, pcap, KV, D), (B, lb, KV, D)]
    args = [jax.random.normal(k_, s) * 2 for k_, s in zip(ks, shapes)]
    od = ops.apb_attention(*args, anchor_valid=la, pass_valid=pcap,
                           softcap=20.0, use_kernel="decomposed")
    orf = ops.apb_attention(*args, anchor_valid=la, pass_valid=pcap,
                            softcap=20.0, use_kernel=False)
    np.testing.assert_allclose(np.asarray(od[1]), np.asarray(orf[1]),
                               atol=2e-5, rtol=2e-5)


def test_chunked_causal_matches_reference(key):
    from repro.kernels import ref
    q = jax.random.normal(key, (2, 100, 4, 16))
    k = jax.random.normal(jax.random.fold_in(key, 1), (2, 100, 2, 16))
    v = jax.random.normal(jax.random.fold_in(key, 2), (2, 100, 2, 16))
    out = ref.chunked_causal_attention(q, k, v, chunk=32)
    expect = ref.causal_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               atol=2e-5, rtol=2e-5)
