"""Property-based tests (hypothesis) on the system's core invariants.

``hypothesis`` is an optional dev dependency (``pip install -e .[dev]``);
without it this module degrades to a skip instead of a collection error.
"""
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.decode import partial_attention_lse
from repro.core.splitting import make_layout, augment_indices, \
    augment_positions, local_block_indices
from repro.kernels import ref
from repro.parallel.collectives import lse_merge_pair
from repro.training import optimizer as opt

hypothesis.settings.register_profile(
    "ci", deadline=None, max_examples=20,
    suppress_health_check=[hypothesis.HealthCheck.too_slow])
hypothesis.settings.load_profile("ci")


@given(st.integers(1, 4), st.integers(2, 6), st.integers(0, 1000))
def test_lse_merge_is_exact_partition(b, splits, seed):
    """Splitting a KV set arbitrarily and LSE-merging partials must equal
    attention over the whole set — the invariant behind paper Alg. 3."""
    key = jax.random.PRNGKey(seed)
    L, H, D = 24, 2, 8
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, 1, H, D))
    k = jax.random.normal(ks[1], (b, L, H, D))
    v = jax.random.normal(ks[2], (b, L, H, D))
    full, _ = partial_attention_lse(q, k, v)
    bounds = np.linspace(0, L, splits + 1).astype(int)
    out, lse = partial_attention_lse(q, k[:, :bounds[1]], v[:, :bounds[1]])
    for i in range(1, splits):
        o2, l2 = partial_attention_lse(
            q, k[:, bounds[i]:bounds[i + 1]], v[:, bounds[i]:bounds[i + 1]])
        out, lse = lse_merge_pair(out, lse, o2, l2)
    np.testing.assert_allclose(np.asarray(out), np.asarray(full),
                               atol=1e-5, rtol=1e-5)


@given(st.integers(8, 64), st.integers(1, 8), st.integers(1, 8),
       st.integers(0, 100))
def test_apb_mask_invariants(lb, la, lp, seed):
    """Structural invariants of the APB visibility mask."""
    pcap = 4 * lp
    rng = np.random.default_rng(seed)
    av = int(rng.choice([0, la]))
    pv = int(rng.integers(0, pcap + 1))
    m = np.asarray(ref.apb_mask(la + lb, la + pcap + lb, la=la, pcap=pcap,
                                anchor_valid=av, pass_valid=pv))
    # 1. anchor queries never see passing or local keys
    assert not m[:la, la:].any()
    # 2. nothing sees invalid anchor/passing entries
    assert not m[:, av:la].any()
    assert not m[:, la + pv:la + pcap].any()
    # 3. local block is causal: strictly-upper triangle empty
    loc = m[la:, la + pcap:]
    assert not np.triu(loc, 1).any()
    # 4. every local query sees itself
    assert np.diag(loc).all()
    # 5. all local queries see all valid passing entries
    assert m[la:, la:la + pv].all()


@given(st.integers(1, 16), st.sampled_from([1, 2, 4, 8]),
       st.integers(64, 512))
def test_layout_partition(lq, hosts, n_mult):
    """Augmented-sequence index map covers every doc token exactly once in
    the local blocks and preserves true positions."""
    n = hosts * n_mult
    lay = make_layout(n, lq, hosts)
    idx = augment_indices(lay)
    pos = augment_positions(lay)
    assert len(idx) == lay.aug_len == len(pos)
    loc = local_block_indices(lay)
    doc_ids = idx[loc] - lq                      # positions in the document
    np.testing.assert_array_equal(np.sort(doc_ids), np.arange(n))
    # local tokens carry their true positions
    np.testing.assert_array_equal(pos[loc], lq + doc_ids)


@given(st.integers(0, 2**31 - 1))
def test_adamw_step_shrinks_towards_gradient(seed):
    key = jax.random.PRNGKey(seed)
    p = {"w": jax.random.normal(key, (8, 8))}
    g = {"w": jnp.ones((8, 8))}
    st_ = opt.adamw_init(p)
    cfg = opt.AdamWConfig(lr=1e-2, warmup_steps=0, schedule="constant",
                          clip_norm=None)
    p2, st2, gnorm = opt.adamw_update(cfg, g, st_, p)
    # positive gradient -> parameters decrease
    assert bool(jnp.all(p2["w"] < p["w"]))
    assert st2.step == 1
    assert np.isclose(float(gnorm), 8.0)         # ||ones(8x8)|| = 8


@given(st.integers(2, 64), st.integers(0, 1000), st.booleans())
def test_softmax_attention_is_convex_combination(L, seed, causal):
    """Attention outputs lie in the convex hull of V (rows bounded by V's
    min/max per dim) — catches mask/normalisation bugs."""
    key = jax.random.PRNGKey(seed)
    q = jax.random.normal(key, (1, L, 2, 8))
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, L, 2, 8))
    v = jax.random.normal(jax.random.fold_in(key, 2), (1, L, 2, 8))
    out = ref.causal_attention_ref(q, k, v, causal=causal)
    vmin, vmax = float(v.min()), float(v.max())
    assert float(out.min()) >= vmin - 1e-4
    assert float(out.max()) <= vmax + 1e-4
