"""tools/ gate scripts as importable modules (satellite of the static-
analysis PR): check_links and check_bench_results must be drivable from
tests without subprocesses, and all gate tools share tools/reporting.py
conventions — ``FAIL <detail>`` lines, one summary line, exit 0 iff
clean."""
import json
from pathlib import Path

from tools import check_bench_results, check_links, reporting

ROOT = Path(__file__).resolve().parent.parent


# ---------------------------------------------------------------------------
# reporting conventions
# ---------------------------------------------------------------------------

def test_report_ok_exit_code_and_summary(capsys):
    assert reporting.report("mytool", [], "2 file(s)") == 0
    out = capsys.readouterr().out
    assert out == "mytool: ok (0 finding(s); 2 file(s))\n"


def test_report_failures_one_line_each(capsys):
    assert reporting.report("mytool", ["a: broken", "b: broken"],
                            "scope") == 1
    lines = capsys.readouterr().out.splitlines()
    assert lines == ["FAIL a: broken", "FAIL b: broken",
                     "mytool: FAIL (2 finding(s); scope)"]


# ---------------------------------------------------------------------------
# check_links
# ---------------------------------------------------------------------------

def _md_tree(tmp_path, readme):
    (tmp_path / "docs").mkdir()
    (tmp_path / "docs" / "a.md").write_text("# a\n")
    (tmp_path / "README.md").write_text(readme)
    return tmp_path


def test_check_links_clean_tree(tmp_path):
    root = _md_tree(tmp_path, "[a](docs/a.md) [ext](https://x.y) [top](#h)\n")
    assert check_links.check(check_links.default_files(root), root) == []


def test_check_links_reports_broken_relative_link(tmp_path):
    root = _md_tree(tmp_path, "[gone](docs/missing.md)\n")
    broken = check_links.check(check_links.default_files(root), root)
    assert broken == ["README.md: broken link -> docs/missing.md"]


def test_check_links_anchor_suffix_checks_path_only(tmp_path):
    root = _md_tree(tmp_path, "[a](docs/a.md#section)\n")
    assert check_links.check(check_links.default_files(root), root) == []


def test_check_links_ignores_fenced_code_examples(tmp_path):
    root = _md_tree(tmp_path,
                    "```\n[ex](not/a/real/file.md)\n```\n[a](docs/a.md)\n")
    assert check_links.check(check_links.default_files(root), root) == []


def test_repo_docs_have_no_broken_links():
    files = check_links.default_files(ROOT)
    assert check_links.check(files, ROOT) == []


# ---------------------------------------------------------------------------
# check_bench_results
# ---------------------------------------------------------------------------

def _artifact(tmp_path, name, doc):
    p = tmp_path / f"{name}.json"
    p.write_text(json.dumps(doc))
    return p


GOOD = {"benchmark": "x",
        "records": [{"name": "r", "us_per_call": 1.0, "derived": {}}]}


def test_bench_valid_artifact_passes(tmp_path):
    _artifact(tmp_path, "bench_x", GOOD)
    assert check_bench_results.check(str(tmp_path), ["bench_x"]) == []


def test_bench_missing_artifact_fails(tmp_path):
    errs = check_bench_results.check(str(tmp_path), ["bench_x"])
    assert len(errs) == 1 and "missing" in errs[0]


def test_bench_unparseable_and_empty_records_fail(tmp_path):
    (tmp_path / "bench_a.json").write_text("{not json")
    _artifact(tmp_path, "bench_b", {"benchmark": "b", "records": []})
    errs = check_bench_results.check(str(tmp_path), ["bench_a", "bench_b"])
    assert any("unreadable JSON" in e for e in errs)
    assert any("no records" in e for e in errs)


def test_bench_schema_drift_fails(tmp_path):
    doc = {"benchmark": "x", "records": [{"name": "r"}]}   # lost columns
    _artifact(tmp_path, "bench_x", doc)
    errs = check_bench_results.check(str(tmp_path), ["bench_x"])
    assert sorted(errs) == [
        f"{tmp_path}/bench_x.json: records[0] lacks 'derived'",
        f"{tmp_path}/bench_x.json: records[0] lacks 'us_per_call'",
    ]


def test_bench_default_names_track_tiny_sweep():
    names = check_bench_results.default_names()
    assert names and all(n.startswith("bench_") for n in names)
