"""Whisper-style enc-dec: prefill + decode-step consistency and the
bidirectional-encoder APB variant."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import encdec
from repro.models import model as model_lib
from repro.models.transformer import RunCtx

B, S, LQ = 2, 32, 6


@pytest.fixture()
def setup(key):
    cfg = get_config("whisper-tiny").reduced()
    model = model_lib.build(cfg)
    params = model.init(key)
    frames = jax.random.normal(key, (B, S, cfg.d_model)) * 0.05
    toks = jax.random.randint(jax.random.fold_in(key, 1), (B, LQ), 0,
                              cfg.vocab_size)
    return cfg, model, params, frames, toks


def test_prefill_then_decode_matches_teacher_forcing(setup, key):
    cfg, model, params, frames, toks = setup
    rctx = RunCtx(strategy="full")
    lg, xc, tails = model.prefill_step(params, frames, toks, rctx)
    nxt = jnp.argmax(lg, -1)[:, None].astype(jnp.int32)

    # teacher-forcing reference over [toks, nxt]
    enc_out = encdec.encode(params, cfg, frames, rctx)
    xc_ref = encdec.cross_kv(params, cfg, enc_out)
    hidden, _ = encdec.decode_tokens(params, cfg,
                                     jnp.concatenate([toks, nxt], 1),
                                     xc_ref, None, rctx)
    lg_ref = encdec.logits(params, cfg, hidden[:, -1:])[:, 0]

    lg2, _ = model.serve_step(params, nxt, LQ, xc, tails, rctx)
    np.testing.assert_allclose(np.asarray(lg2), np.asarray(lg_ref),
                               atol=5e-4, rtol=1e-3)


def test_encoder_bidirectional(setup, key):
    """Every encoder position must influence every output (no causal
    mask leaking into the encoder).  NB: perturb with a random vector —
    a constant bump is annihilated by LayerNorm's mean subtraction."""
    cfg, model, params, frames, toks = setup
    rctx = RunCtx(strategy="full")
    out1 = encdec.encode(params, cfg, frames, rctx)
    noise = jax.random.normal(jax.random.fold_in(key, 99),
                              (frames.shape[0], frames.shape[2]))
    bumped = frames.at[:, -1].add(noise)    # change only the LAST frame
    out2 = encdec.encode(params, cfg, bumped, rctx)
    delta = jnp.abs(out2 - out1).max(axis=(0, 2))
    assert float(delta[0]) > 1e-5, \
        f"first output blind to last frame: {float(delta[0])}"


def test_seq2seq_loss_finite(setup):
    cfg, model, params, frames, toks = setup
    loss = model.loss_fn(params, (frames, toks), RunCtx(strategy="full"))
    assert bool(jnp.isfinite(loss))
